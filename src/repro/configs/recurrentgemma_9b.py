"""recurrentgemma-9b — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427 (Griffin)].

38L (12 x (rec, rec, attn) + (rec, rec)), d_model=4096, 16H (MQA kv=1),
d_ff=12288, vocab=256000, local window 2048. Sub-quadratic state => eligible
for the long_500k decode cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    hybrid_pattern=("rec", "rec", "attn"),
    attn_window=2048,
    rnn_width=4096,
    gated_mlp=True,
)

SMOKE = CONFIG.replace(
    num_layers=5,  # exercises both segments: 1 full unit + (rec, rec) rest
    d_model=64, num_heads=4, num_kv_heads=1, head_dim=16, d_ff=128,
    vocab_size=256, attn_window=16, rnn_width=64,
)
