"""Architecture registry: ``--arch <id>`` resolution for launch/dryrun/train.

Each module defines ``CONFIG`` (the exact public configuration) and ``SMOKE``
(a reduced same-family variant for CPU tests).
"""

from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, input_specs  # noqa: F401

_MODULES = {
    "mamba2-130m": "mamba2_130m",
    "qwen2.5-3b": "qwen2_5_3b",
    "internlm2-20b": "internlm2_20b",
    "stablelm-12b": "stablelm_12b",
    "granite-8b": "granite_8b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "arctic-480b": "arctic_480b",
    "internvl2-26b": "internvl2_26b",
    "whisper-base": "whisper_base",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

ARCHS = tuple(_MODULES)


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def cells(include_skips: bool = False):
    """All (arch, shape) dry-run cells; skipped cells annotated with reason."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        skips = cfg.shape_skips()
        for shape in SHAPES:
            if shape in skips and not include_skips:
                continue
            out.append((arch, shape, skips.get(shape)))
    return out
