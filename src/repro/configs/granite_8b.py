"""granite-8b — llama-arch code model [arXiv:2405.04324].

36L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=49152.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
)
