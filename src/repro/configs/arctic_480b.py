"""arctic-480b — 128-expert top-2 MoE + parallel dense residual FFN
[hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56H (GQA kv=8), d_ff=4864, vocab=32000.
Note: 56 heads are not divisible by the 16-way model axis; activation head
sharding is relaxed per DESIGN.md §4 (params still shard on the fused dim).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32000,
    moe_num_experts=128,
    moe_top_k=2,
    moe_d_ff=4864,
    moe_dense_ff=4864,     # dense residual path
    moe_group_size=1024,   # §Perf iter 3: dispatch GEMM flops/token ∝ group
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    vocab_size=256, moe_num_experts=8, moe_top_k=2, moe_d_ff=32,
    moe_dense_ff=32, moe_group_size=64,
)
