"""End-to-end LM training driver (example application b).

Default invocation trains a ~15M-parameter mamba2-family model for 200 steps
on the synthetic token pipeline — small enough to finish on the CPU container
while exercising the full production path (jit train step, AdamW + cosine,
checkpoint/resume, NaN guard, heartbeat).

The real 130M run is the same command with ``--full``:

    PYTHONPATH=src python examples/train_lm.py --full --steps 300 \
        --batch 16 --seq 1024        # (sized for a real accelerator)
"""

import argparse

from repro.configs import get_config
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full", action="store_true",
                    help="train the full assigned config (accelerator-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="artifacts/example_lm_ckpt")
    args = ap.parse_args()

    if args.full:
        out = train(args.arch, smoke=False, steps=args.steps, batch=args.batch,
                    seq=args.seq, ckpt_dir=args.ckpt_dir)
    else:
        # ~15M-param same-family variant: full depth, reduced width
        from repro.launch import train as train_mod
        import repro.configs as cfgs

        base = get_config(args.arch)
        small = base.replace(d_model=256, num_heads=8, num_kv_heads=8,
                             vocab_size=8192,
                             **({"d_ff": 1024} if base.d_ff else {}))
        # monkey-path-free: call the internals directly
        from repro.launch.train import train as _train
        import repro.launch.train as t

        orig = t.get_config
        t.get_config = lambda name: small
        try:
            out = _train(args.arch, smoke=False, steps=args.steps,
                         batch=args.batch, seq=args.seq,
                         ckpt_dir=args.ckpt_dir)
        finally:
            t.get_config = orig

    print(f"[example] initial loss {out['losses'][0]:.4f} -> "
          f"final {out['losses'][-1]:.4f} over {len(out['losses'])} steps")
    assert out["losses"][-1] < out["losses"][0], "training must reduce loss"


if __name__ == "__main__":
    main()
