"""Sparse-probe head: the paper's technique attached to an LM backbone.

Pipeline (the production integration described in DESIGN.md §4):
  1. briefly train a small LM on the synthetic stream,
  2. freeze it and extract last-layer features for a labeled probe task,
  3. treat the d_model feature dimensions as SVM *features* (paper layout
     X: features x samples) and fit an L1-L2-SVM **path with safe
     screening** to select a sparse, interpretable subset.

    PYTHONPATH=src python examples/sparse_probe.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import svm_path
from repro.launch.steps import init_train_state, make_train_step
from repro.data import TokenPipeline
from repro.models import transformer as tr
from repro.models.layers import embed, rmsnorm


def extract_features(params, cfg, tokens):
    """Frozen-backbone features: final-norm hidden state at the last position."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = embed(params["embed"], tokens, act_dtype=jnp.float32)
    x, _, _ = tr._run_segments(params, cfg, x, positions, None, None, "train")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x[:, -1]  # (B, d_model)


def main():
    cfg = get_smoke_config("qwen2.5-3b").replace(dtype="float32")

    # 1) short backbone training run
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, total_steps=30))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch_size=8, seq_len=64)
    for s in range(30):
        state, metrics = step(state, jax.tree_util.tree_map(
            jnp.asarray, pipe.batch_at(s)))
    print(f"[probe] backbone trained, final LM loss {float(metrics['loss']):.3f}")

    # 2) probe task: does the sequence end in an even token? (synthetic labels)
    feat_fn = jax.jit(lambda t: extract_features(state.params, cfg, t))
    rng = np.random.default_rng(1)
    n = 192
    toks = rng.integers(0, cfg.vocab_size, (n, 64)).astype(np.int32)
    feats = np.asarray(feat_fn(jnp.asarray(toks)))          # (n, d_model)
    y = np.where(toks[:, -1] % 2 == 0, 1.0, -1.0).astype(np.float32)

    # 3) screened sparse-SVM path over the d_model feature dims
    X = feats.T.astype(np.float32)                          # features x samples
    X = (X - X.mean(1, keepdims=True)) / (X.std(1, keepdims=True) + 1e-9)
    path = svm_path(X, y, n_lambdas=6, lam_min_ratio=0.15)
    print("[probe] kept feature-dims per lambda :", path.kept.tolist())
    print("[probe] active (selected) dims       :", path.active.tolist())
    sel = np.nonzero(np.abs(path.weights[-1]) > 1e-8)[0]
    print(f"[probe] final sparse probe uses {len(sel)}/{X.shape[0]} dims: "
          f"{sel[:12].tolist()}{'...' if len(sel) > 12 else ''}")

    # probe accuracy (train-set; demonstration)
    pred = np.sign(path.weights[-1] @ X + path.biases[-1])
    acc = float(np.mean(pred == y))
    print(f"[probe] fit accuracy {acc:.3f}")


if __name__ == "__main__":
    main()
