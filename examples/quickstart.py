"""Quickstart: safe screening for the sparse SVM in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PathDriver,
    available_rules,
    fista_solve,
    lambda_max,
    screen,
    svm_path,
    theta_at_lambda_max,
)
from repro.data import make_sparse_classification

# 1. data: 2000 features x 300 samples, 12 truly-informative features
ds = make_sparse_classification(m=2000, n=300, k_active=12, seed=0)
X, y = jnp.asarray(ds.X), jnp.asarray(ds.y)

# 2. lambda_max in closed form (paper Eq. 26): above it, w* = 0
lmax = float(lambda_max(X, y))
print(f"lambda_max = {lmax:.3f}")

# 3. screen features for lambda = 0.7*lmax using the exact dual point at lmax
#    (screening power grows as lambda2 -> lambda1; the path below shows the
#    sequential rule staying strong across the whole grid)
theta1 = theta_at_lambda_max(y, jnp.asarray(lmax))
lam2 = 0.7 * lmax
keep, bounds = screen(X, y, lmax, lam2, theta1)
print(f"screening keeps {int(keep.sum())}/{X.shape[0]} features "
      f"(rejected {100 * (1 - float(keep.mean())):.1f}%)")

# 4. solve the reduced problem — same solution, fraction of the work
idx = np.nonzero(np.asarray(keep))[0]
res_red = fista_solve(jnp.asarray(np.asarray(X)[idx]), y, lam2,
                      max_iters=20000, tol=1e-10)
res_full = fista_solve(X, y, lam2, max_iters=20000, tol=1e-10)
print(f"objective reduced={float(res_red.obj):.6f} full={float(res_full.obj):.6f} "
      f"(identical => screening was safe)")

# 5. a whole regularization path with sequential screening
path = svm_path(ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.1)
print("path kept counts :", path.kept.tolist())
print("path active nnz  :", path.active.tolist())

# 6. comparing screening rules (the pluggable-rule registry, core/rules):
#    - "feature_vi"  the paper's safe feature rule: shrinks the m-axis
#    - "sample_vi"   margin-predicted + KKT-verified sample rule: shrinks the
#                    n-axis (power grows as lambda shrinks and more samples
#                    clear the margin)
#    - "composite"   both at once: solver cost ~ kept_m x kept_n
#    All produce the same path (screening is exact); they differ in how much
#    of the problem the solver never has to touch.
print(f"\nregistered rules: {available_rules()}")
for spec in ("feature_vi", "sample_vi", "composite", "dvi"):
    r = PathDriver(rules=spec).run(ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.02)
    print(f"{spec:10s} kept features {r.kept.tolist()}")
    print(f"{'':10s} kept samples  {r.kept_samples.tolist()} "
          f"(verify re-solves: {int(r.verify_rounds.sum())})")

# 7. dynamic screening: the region certifying theta*(lambda) keeps shrinking
#    while FISTA converges, so the solver re-screens itself every
#    screen_every iterations — the feature mask tightens MID-solve, beyond
#    what the between-lambda sequential screen could certify
dyn = PathDriver(rules="feature_vi", dynamic=True, screen_every=25).run(
    ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.02)
print("\ndynamic in-solver tightening (per-step kept trajectory):")
for k, tele in sorted(dyn.extras["dynamic"].items()):
    if tele["kept_per_segment"] and tele["kept_per_segment"][-1] < dyn.kept[k]:
        print(f"  step {k}: initial screen kept {int(dyn.kept[k])} "
              f"-> segments {tele['kept_per_segment']}")

# 8. the on-device path engine: the SAME screened path as one jitted
#    lax.scan program — zero host round trips between lambda steps. Use it
#    when solves are fast and orchestration dominates (engine="host" keeps
#    the gather-mode FLOP reduction and verified sample rules). A batch of
#    grids/problems vmaps onto one program via core.svm_path_batched.
import time

svm_path(ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.1, engine="scan")  # compile
t0 = time.perf_counter()
scan = svm_path(ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.1, engine="scan")
t_scan = time.perf_counter() - t0
print(f"\nscan engine: {t_scan:.3f}s "
      f"(obj match host: "
      f"{float(abs(scan.objectives - path.objectives).max()):.2e})")

# 9. compact reduction: the scan engine turns each step's certified keep
#    mask into a physically gathered fixed-capacity active set INSIDE the
#    jitted program (cumsum compaction into a static bucket, mask fallback
#    on overflow), so solver FLOPs track what screening keeps — the paper's
#    compute reduction, realized with zero host sync. Rule of thumb:
#      gather  (host)  multiplicative feature x sample cut, verified rules;
#      mask    (scan)  weak screening, or vmapped/batched paths;
#      compact (scan)  screening certifies a small active set (small caps
#                      below) — FLOP-proportional AND single-program.
svm_path(ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.1, engine="scan",
         reduce="compact")  # compile (one solver body per bucket)
t0 = time.perf_counter()
comp = svm_path(ds.X, ds.y, n_lambdas=8, lam_min_ratio=0.1, engine="scan",
                reduce="compact")
print(f"compact scan: {time.perf_counter() - t0:.3f}s (mask {t_scan:.3f}s; "
      "the gap widens with screening power — see BENCH_screening.json)")
print("  kept :", comp.kept.tolist())
print("  caps :", comp.extras["caps"].tolist(),
      " (buffer the step actually solved in; m = mask fallback)")
print("  resurrected per step:", comp.extras["resurrected"].tolist())

# 10. out-of-core storage: when X does not fit on the device, hold it as
#     host-resident feature chunks (dense or CSR — low-density chunks sweep
#     as BCOO so FLOPs track nnz). The bound sweep streams chunk by chunk
#     (bitwise the in-core sweep on dense chunks) and the solver only ever
#     sees the gathered rows that survive screening: peak device memory is
#     O(chunk + kept), never O(m*n). Same API — pass the container where X
#     would go.
from repro.sparse import FeatureChunked

sp = make_sparse_classification(m=4000, n=300, k_active=12, density=0.05,
                                seed=0)
fc = FeatureChunked.from_csr(sp.csr, chunk_m=512)   # or .from_dense(sp.X, ...)
oc = svm_path(fc, sp.y, n_lambdas=8, lam_min_ratio=0.1)
ref = svm_path(sp.X, sp.y, n_lambdas=8, lam_min_ratio=0.1)
print(f"\nout-of-core path (storage=csr, {fc.n_chunks} chunks): "
      f"obj match dense: "
      f"{float(abs(oc.objectives - ref.objectives).max()):.2e}")
print("  max feature rows ever on device:",
      oc.extras["stream_stats"]["max_put_rows"], f"of m={fc.shape[0]}",
      f"(BCOO transfers: {oc.extras['stream_stats']['bcoo_puts']})")

# 11. serving a mixed workload: many small path problems with ragged grids
#     drain through the continuous-batching path server — jobs pad into
#     power-of-two shape buckets, every resident job advances one lambda
#     step per call of ONE jitted step program (compact reduction shares a
#     single capacity across the batch), and slots refill the moment a path
#     certifies its last step. The warm program cache means a handful of
#     compiles serves ANY mix of grid lengths, where sequential svm_path
#     would retrace per shape.
from repro.launch.path_server import PathJob, PathServer

mix = [PathJob(jid=i, X=d.X, y=d.y, n_lambdas=t, lam_min_ratio=0.2)
       for i, (d, t) in enumerate(
           (make_sparse_classification(m=200, n=90, k_active=8, seed=30 + i),
            t) for i, t in enumerate((4, 7, 5, 9)))]
server = PathServer(slots=2, reduce="compact")
results = server.serve(mix, log=lambda *a, **k: None)
seq = svm_path(mix[0].X, mix[0].y, lambdas=mix[0].lambdas, engine="scan",
               reduce="compact")
print("\npath server (4 ragged jobs, 2 slots):")
print(f"  jobs/s {server.last_serve['jobs_per_s']:.2f}, "
      f"occupancy {server.last_serve['slot_occupancy']:.2f}, "
      f"programs {server.last_serve['programs']} "
      f"(hits {server.last_serve['hits']}, retraces "
      f"{server.last_serve['retraces']})")
print("  grid lengths :", [len(j.lambdas) for j in mix])
print(f"  job 0 vs sequential svm_path obj diff: "
      f"{float(abs(results[0].objectives - seq.objectives).max()):.2e}")
